"""AdamW with decoupled weight decay, global-norm clipping, and warmup +
cosine decay — implemented directly (no optax) so every state tensor can be
sharded with the same rules as its parameter.

State layout mirrors the parameter pytree: ``m`` and ``v`` are pytrees with
identical structure (and therefore identical ``NamedSharding``), plus a
scalar step counter.  Keeping optimizer moments in fp32 while parameters are
bf16 is the standard mixed-precision recipe; the fp32 master copy is the
moments' co-located ``master`` tree (optional, enabled by default).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1          # cosine floor as a fraction of lr
    use_master_fp32: bool = True      # keep an fp32 master parameter copy


class AdamWState(NamedTuple):
    step: Array            # scalar int32
    m: PyTree              # fp32, same structure as params
    v: PyTree              # fp32
    master: Optional[PyTree]  # fp32 master params (None if disabled)


def adamw_init(params: PyTree, config: AdamWConfig) -> AdamWState:
    zeros32 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = (
        # explicit copy: with fp32 params, astype would alias the parameter
        # buffer and break donation (donate-same-buffer-twice)
        jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True), params)
        if config.use_master_fp32
        else None
    )
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=zeros32,
        v=jax.tree.map(jnp.copy, zeros32),
        master=master,
    )


def lr_schedule(step: Array, config: AdamWConfig) -> Array:
    """Linear warmup then cosine decay to ``min_lr_frac * lr``."""
    step_f = step.astype(jnp.float32)
    warm = jnp.minimum(step_f / jnp.maximum(config.warmup_steps, 1), 1.0)
    progress = jnp.clip(
        (step_f - config.warmup_steps)
        / jnp.maximum(config.total_steps - config.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    floor = config.min_lr_frac
    return config.lr * warm * (floor + (1.0 - floor) * cos)


def global_norm(tree: PyTree) -> Array:
    leaves = [
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)
    ]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def _decay_mask(path) -> bool:
    """Weight decay applies to matrices only — embeddings and >=2D weights —
    never to norms/biases (1D leaves)."""
    return True  # resolved per-leaf by ndim below


def adamw_update(
    grads: PyTree,
    state: AdamWState,
    params: PyTree,
    config: AdamWConfig,
) -> Tuple[PyTree, AdamWState, Dict[str, Array]]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads32, gnorm = clip_by_global_norm(grads32, config.grad_clip)

    step = state.step + 1
    lr = lr_schedule(step, config)
    b1, b2 = config.b1, config.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads32)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.v, grads32)

    base = state.master if state.master is not None else params

    def upd(p32, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + config.eps)
        wd = config.weight_decay if p32.ndim >= 2 else 0.0
        return p32 - lr * (delta + wd * p32.astype(jnp.float32))

    new_master = jax.tree.map(
        lambda p, m, v: upd(p.astype(jnp.float32), m, v), base, new_m, new_v
    )
    new_params = jax.tree.map(
        lambda p_old, p_new: p_new.astype(p_old.dtype), params, new_master
    )

    new_state = AdamWState(
        step=step,
        m=new_m,
        v=new_v,
        master=new_master if config.use_master_fp32 else None,
    )
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
