"""The jit'd training step: loss -> grads -> (optional cross-pod codec)
-> AdamW, with microbatch gradient accumulation and donated buffers.

Distribution contract (DESIGN.md §6):

* parameters/optimizer state are sharded by ``parallel.sharding.param_specs``
  (FSDP over ``data`` + TP over ``model``; replicated over ``pod``);
* the batch is sharded over ``('pod', 'data')``;
* with ``grad_codec != 'none'`` the step is wrapped in ``shard_map`` manual
  over **only** the ``pod`` axis (``data``/``model`` stay compiler-auto), so
  the cross-DCN gradient hop runs through the bf16/int8 codec while
  intra-pod reduction remains XLA's reduce-scatter/all-gather pipeline.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.registry import get_api
from repro.models.transformer import ParallelRuntime
from repro.parallel import sharding as SH
from repro.training import compression
from repro.training.optimizer import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
)

Array = jax.Array
PyTree = Any


@dataclass(frozen=True)
class TrainStepConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1             # gradient-accumulation chunks
    grad_codec: str = "none"          # none | bf16 | int8 (cross-pod hop)
    seed: int = 0


class TrainState:
    """Bundles params + optimizer state (a plain pytree-of-pytrees)."""

    def __init__(self, params: PyTree, opt: AdamWState):
        self.params = params
        self.opt = opt

    def as_tree(self) -> Dict[str, Any]:
        return {"params": self.params, "opt": self.opt}


def make_runtime(mesh: Optional[Mesh]) -> Optional[ParallelRuntime]:
    if mesh is None:
        return None
    import os
    return ParallelRuntime(
        mesh=mesh,
        dp_axes=SH.dp_axes(mesh),
        tp_axis="model" if "model" in mesh.axis_names else "",
        pin_attn_seq=os.environ.get("REPRO_PIN_ATTN", "1") == "1",
    )


# ---------------------------------------------------------------------------
# state construction (sharded init without materializing on one device)
# ---------------------------------------------------------------------------


def state_shape(cfg: ModelConfig, opt_cfg: AdamWConfig) -> Dict[str, Any]:
    """eval_shape of the full train state (params + AdamW moments)."""
    api = get_api(cfg)
    params = jax.eval_shape(lambda k: api.init(k, cfg), jax.random.PRNGKey(0))
    opt = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params)
    return {"params": params, "opt": opt}


def state_specs(cfg: ModelConfig, opt_cfg: AdamWConfig, mesh: Mesh) -> Dict[str, Any]:
    """PartitionSpecs for the full state — moments/master inherit their
    parameter's spec; the step counter is replicated."""
    shapes = state_shape(cfg, opt_cfg)
    pspecs = SH.param_specs(shapes["params"], mesh)
    opt_specs = AdamWState(
        step=P(),
        m=pspecs,
        v=pspecs,
        master=pspecs if opt_cfg.use_master_fp32 else None,
    )
    return {"params": pspecs, "opt": opt_specs}


def make_sharded_train_state(
    cfg: ModelConfig,
    mesh: Optional[Mesh],
    ts_cfg: TrainStepConfig = TrainStepConfig(),
) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Returns (state_tree, state_specs).  With a mesh, init is jit'd with
    out_shardings so each device materializes only its shard."""
    api = get_api(cfg)

    def init_all(key):
        params = api.init(key, cfg)
        return {"params": params, "opt": adamw_init(params, ts_cfg.optimizer)}

    key = jax.random.PRNGKey(ts_cfg.seed)
    if mesh is None:
        return init_all(key), None
    specs = state_specs(cfg, ts_cfg.optimizer, mesh)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    state = jax.jit(init_all, out_shardings=shardings)(key)
    return state, specs


# ---------------------------------------------------------------------------
# the step
# ---------------------------------------------------------------------------


def _microbatch(batch: Dict[str, Array], n: int, i: Array) -> Dict[str, Array]:
    def slice_one(x: Array) -> Array:
        b = x.shape[0]
        mb = b // n
        return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

    return jax.tree.map(slice_one, batch)


def make_train_step(
    cfg: ModelConfig,
    mesh: Optional[Mesh],
    ts_cfg: TrainStepConfig = TrainStepConfig(),
    *,
    state_partition: Optional[Dict[str, Any]] = None,
    batch_shape: Optional[Dict[str, Any]] = None,
) -> Callable[[Dict[str, Any], Dict[str, Array]], Tuple[Dict[str, Any], Dict[str, Array]]]:
    """Builds the jit'd ``step(state, batch) -> (state, metrics)``.

    ``state_partition``/``batch_shape`` are needed only when a mesh is given
    (they pin in/out shardings so ``.lower()`` works from ShapeDtypeStructs).
    """
    api = get_api(cfg)
    rt = make_runtime(mesh)
    n_micro = ts_cfg.microbatches

    def loss_fn(params, batch):
        return api.loss(params, batch, cfg, rt)

    def grads_of(params, batch):
        if n_micro == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def body(carry, i):
            loss_acc, grad_acc = carry
            mb = _microbatch(batch, n_micro, i)
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            grad_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), grad_acc, grads
            )
            return (loss_acc + loss, grad_acc), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (loss_sum, grad_sum), _ = jax.lax.scan(
            body, (jnp.float32(0.0), zeros), jnp.arange(n_micro)
        )
        inv = 1.0 / n_micro
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, grad_sum)

    def apply_grads(state, loss, grads):
        new_params, new_opt, metrics = adamw_update(
            grads, state["opt"], state["params"], ts_cfg.optimizer
        )
        metrics = dict(metrics, loss=loss)
        return {"params": new_params, "opt": new_opt}, metrics

    use_codec = (
        ts_cfg.grad_codec != "none"
        and mesh is not None
        and "pod" in mesh.axis_names
    )

    if not use_codec:
        def step(state, batch):
            loss, grads = grads_of(state["params"], batch)
            return apply_grads(state, loss, grads)
    else:
        n_pods = mesh.shape["pod"]

        # Per-pod gradients via vmap over pod-chunks of the batch, with the
        # leading chunk dim sharded over 'pod' — each pod computes only its
        # own grads under auto-SPMD, and the codec'd sum over that dim is
        # the one cross-DCN collective (int8: an int accumulation of
        # quantized grads on a shared absmax grid; bf16: half-width).
        # Inside the vmap, 'pod' is the vmapped dim, so the runtime keeps
        # only the intra-pod dp axes.
        rt_inner = ParallelRuntime(
            mesh=mesh,
            dp_axes=tuple(a for a in SH.dp_axes(mesh) if a != "pod"),
            tp_axis="model" if "model" in mesh.axis_names else "",
        )

        def grads_one_pod(params, pod_batch):
            loss, grads = jax.value_and_grad(
                lambda p: api.loss(p, pod_batch, cfg, rt_inner)
            )(params)
            return loss, jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        pspecs_params = state_partition["params"]

        def _pod_sharded(tree):
            """Constrain a per-pod-stacked tree: leading dim on 'pod',
            remaining dims per the parameter's own spec."""
            def one(x, spec):
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P("pod", *spec))
                )
            return jax.tree.map(one, tree, pspecs_params, is_leaf=None)

        def step(state, batch):
            pod_batch = jax.tree.map(
                lambda x: x.reshape(n_pods, x.shape[0] // n_pods, *x.shape[1:]),
                batch,
            )
            losses, pod_grads = jax.vmap(grads_one_pod, in_axes=(None, 0))(
                state["params"], pod_batch
            )
            pod_grads = _pod_sharded(pod_grads)
            loss = jnp.mean(losses)

            if ts_cfg.grad_codec == "bf16":
                grads = jax.tree.map(
                    lambda g: jnp.sum(g.astype(jnp.bfloat16).astype(jnp.float32), axis=0)
                    / n_pods,
                    pod_grads,
                )
            else:  # int8 stochastic rounding on a shared absmax grid
                key0 = jax.random.fold_in(
                    jax.random.PRNGKey(ts_cfg.seed), state["opt"].step
                )
                leaves, treedef = jax.tree.flatten(pod_grads)
                keys = jax.random.split(key0, len(leaves))

                def enc_dec(g, k):
                    scale = jnp.maximum(jnp.max(jnp.abs(g)) / 127.0, 1e-30)
                    noise = jax.random.uniform(k, g.shape)
                    q = jnp.floor(g / scale + noise).astype(jnp.int8)
                    summed = jnp.sum(q.astype(jnp.int32), axis=0)
                    return summed.astype(jnp.float32) * scale / n_pods

                grads = jax.tree.unflatten(
                    treedef, [enc_dec(g, k) for g, k in zip(leaves, keys)]
                )
            return apply_grads(state, loss, grads)

    if mesh is None:
        return jax.jit(step, donate_argnums=(0,))

    assert state_partition is not None and batch_shape is not None
    gb = _gb(batch_shape)
    state_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), state_partition, is_leaf=_is_spec
    )
    batch_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        SH.batch_specs(batch_shape, mesh, global_batch=gb),
        is_leaf=_is_spec,
    )
    metric_shardings = {
        "loss": NamedSharding(mesh, P()),
        "grad_norm": NamedSharding(mesh, P()),
        "lr": NamedSharding(mesh, P()),
    }
    return jax.jit(
        step,
        in_shardings=(state_shardings, batch_shardings),
        out_shardings=(state_shardings, metric_shardings),
        donate_argnums=(0,),
    )


def _is_spec(x) -> bool:
    return isinstance(x, P)


def _pod_view(spec: P) -> P:
    """Project a spec onto the 'pod' axis only (manual-pod shard_map specs
    may not mention auto axes); params are pod-replicated -> all-None."""
    def clean(entry):
        if isinstance(entry, (tuple, list)):
            return "pod" if "pod" in entry else None
        return "pod" if entry == "pod" else None

    return P(*(clean(e) for e in spec))


def _pod_only(spec: P) -> P:
    """Keep only the 'pod' factor of each entry (batch specs inside manual)."""
    def clean(entry):
        if isinstance(entry, (tuple, list)):
            return "pod" if "pod" in entry else None
        return "pod" if entry == "pod" else None

    return P(*(clean(e) for e in spec))


def _gb(batch_shape: Dict[str, Any]) -> int:
    return int(next(iter(jax.tree.leaves(batch_shape))).shape[0])
