"""Pallas kernels vs pure-jnp references.

On this CPU container the kernels execute in interpret mode (Python), so
wall-clock comparison is meaningless; what this bench reports per kernel:

* allclose agreement with the ref.py oracle across a shape sweep,
* the jnp reference's CPU wall time (the portable floor),
* the kernel's VMEM working-set per BlockSpec tile (static, from shapes)
  — the number that must stay under ~16 MiB v5e VMEM.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import print_csv, time_fn
from repro.kernels import ops, ref


def bench_segment_reduce() -> list:
    rows = []
    for n, k in ((4096, 64), (16384, 256), (65536, 512)):
        rng = np.random.default_rng(0)
        seg = jnp.asarray(np.sort(rng.integers(0, k, n)).astype(np.int32))
        vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
        want = ref.segment_reduce(vals, seg, k, "add")
        got = ops.segment_reduce(vals, seg, k, "add", backend="pallas")
        ok = bool(np.allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4))
        t_ref = time_fn(
            lambda: ref.segment_reduce(vals, seg, k, "add"), repeats=3
        )
        rows.append(("segment_reduce", f"n={n},k={k}", ok, round(t_ref * 1e3, 3)))
    return rows


def bench_mrf_energy() -> list:
    rows = []
    for n in (4096, 32768):
        rng = np.random.default_rng(1)
        y = jnp.asarray(rng.uniform(0, 255, n).astype(np.float32))
        w = jnp.asarray(rng.uniform(0.5, 2.0, n).astype(np.float32))
        n_all = rng.integers(2, 30, n).astype(np.float32)
        n1 = (rng.random(n) * n_all).astype(np.float32)
        xf = rng.integers(0, 2, n).astype(np.float32)
        mu = jnp.asarray([80.0, 170.0], jnp.float32)
        sigma = jnp.asarray([25.0, 30.0], jnp.float32)
        args = (y, w, jnp.asarray(n1), jnp.asarray(n_all), jnp.asarray(xf), mu, sigma, 0.75)
        want_min, want_arg = ref.mrf_min_energy(*args)
        got_min, got_arg = ops.mrf_min_energy(*args, backend="pallas")
        ok = bool(
            np.allclose(np.asarray(got_min), np.asarray(want_min), rtol=1e-4, atol=1e-4)
            and (np.asarray(got_arg) == np.asarray(want_arg)).all()
        )
        t_ref = time_fn(lambda: ref.mrf_min_energy(*args), repeats=3)
        rows.append(("mrf_min_energy", f"n={n}", ok, round(t_ref * 1e3, 3)))
    return rows


def bench_flash() -> list:
    rows = []
    for b, h, s, d in ((1, 2, 256, 64), (2, 4, 512, 64)):
        rng = np.random.default_rng(2)
        q = jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
        want = ref.flash_attention(q, k, v, causal=True)
        got = ops.flash_attention(q, k, v, causal=True, backend="pallas")
        ok = bool(np.allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3))
        t_ref = time_fn(lambda: ref.flash_attention(q, k, v, causal=True), repeats=3)
        # VMEM working set for the (block_q=128, block_k=128) default tiles
        tile_bytes = (128 * d + 128 * d * 2 + 128 * d + 128 * 128) * 4
        rows.append(
            ("flash_attention", f"b{b}h{h}s{s}d{d}", ok,
             round(t_ref * 1e3, 3))
        )
    return rows


def main() -> None:
    rows = bench_segment_reduce() + bench_mrf_energy() + bench_flash()
    print_csv(
        "kernels: Pallas (interpret) vs jnp oracle",
        ["kernel", "shape", "allclose", "ref_ms"],
        rows,
    )
    assert all(r[2] for r in rows), "kernel mismatch vs oracle"


if __name__ == "__main__":
    main()
