"""Paper Fig. 4 / §4.3.2 analogue: per-DPP timing breakdown + problem-size
scaling.

The paper's per-DPP analysis found SortByKey + ReduceByKey dominate and
limit scaling.  We reproduce the breakdown by running one EM iteration's
primitive sequence eagerly under the DPP profiler, per dataset, and a
problem-size scaling curve (single core -> scaling is over problem size,
the shape of the work, rather than thread count).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import build_problems, print_csv, time_fn
from repro.core import dpp
from repro.core.pmrf import em as em_mod
from repro.core.pmrf import energy as E


def _one_map_iteration(hoods, model, labels, mu, sigma, mode: str):
    energies = E.label_energies(hoods, model, labels, mu, sigma)
    if mode == "faithful":
        min_e, arg = E.min_energies_faithful(hoods, energies)
    else:
        min_e, arg = E.min_energies_static(energies)
    hood_e = E.hood_energy_sums(hoods, min_e)
    labels = E.vote_labels(hoods, arg, hoods.n_regions, int(mu.shape[0]))
    mu, sigma = E.update_parameters(model, labels, mode)
    return labels, mu, sigma, hood_e


def per_dpp_breakdown(mode: str = "faithful") -> list:
    rows = []
    for prob in build_problems():
        hoods, model = prob.problem.hoods, prob.problem.model
        labels = jnp.asarray(prob.labels0)
        mu = jnp.asarray(prob.mu0)
        sigma = jnp.asarray(prob.sigma0)
        with dpp.profiled() as prof:
            for _ in range(3):
                labels, mu, sigma, _ = _one_map_iteration(
                    hoods, model, labels, mu, sigma, mode
                )
        totals = prof.totals()
        counts = prof.counts()
        total = sum(totals.values()) or 1.0
        for name in sorted(totals, key=lambda k: -totals[k]):
            rows.append(
                (
                    prob.name,
                    mode,
                    name,
                    counts[name],
                    round(totals[name] * 1e3, 3),
                    round(100.0 * totals[name] / total, 1),
                )
            )
    return rows


def size_scaling() -> list:
    """Optimization runtime vs problem size (fixed grid density)."""
    rows = []
    for size, grid in ((64, 8), (96, 12), (128, 16), (192, 24)):
        from benchmarks.common import build_problems as bp

        prob = bp(size=size, grid=grid)[0]
        hoods, model = prob.problem.hoods, prob.problem.model
        labels0 = jnp.asarray(prob.labels0)
        mu0 = jnp.asarray(prob.mu0)
        sigma0 = jnp.asarray(prob.sigma0)
        cfg = em_mod.EMConfig(mode="static")
        t = time_fn(
            lambda: em_mod.run_em(hoods, model, labels0, mu0, sigma0, cfg),
            repeats=2,
        )
        rows.append((size, hoods.n_hoods, hoods.n_elements, round(t, 4)))
    return rows


def main() -> None:
    print_csv(
        "fig4a: per-DPP breakdown (3 MAP iterations, eager profiler)",
        ["dataset", "mode", "primitive", "calls", "total_ms", "share_pct"],
        per_dpp_breakdown("faithful"),
    )
    print_csv(
        "fig4b: problem-size scaling (static mode, jit)",
        ["image_size", "n_hoods", "n_elements", "optimize_s"],
        size_scaling(),
    )


if __name__ == "__main__":
    main()
