"""Beyond-paper: faithful (per-iteration SortByKey) vs static (hoisted
segmentation) execution modes.

The paper's own profiling blames SortByKey/ReduceByKey for its scaling
ceiling; the static mode removes the per-iteration sort entirely because
the neighborhood structure is EM-invariant (DESIGN.md §2).  Both modes
produce identical labels; this bench quantifies the win, which is the
PMRF-side baseline-vs-optimized entry of EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import build_problems, print_csv, time_fn
from repro.core.pmrf import em as em_mod


def run(size: int = 96, grid: int = 12) -> list:
    rows = []
    for prob in build_problems(size=size, grid=grid):
        hoods, model = prob.problem.hoods, prob.problem.model
        labels0 = jnp.asarray(prob.labels0)
        mu0 = jnp.asarray(prob.mu0)
        sigma0 = jnp.asarray(prob.sigma0)

        results = {}
        times = {}
        for mode in ("faithful", "static"):
            cfg = em_mod.EMConfig(mode=mode)
            times[mode] = time_fn(
                lambda cfg=cfg: em_mod.run_em(
                    hoods, model, labels0, mu0, sigma0, cfg
                ),
                repeats=3,
            )
            results[mode] = em_mod.run_em(hoods, model, labels0, mu0, sigma0, cfg)

        same = bool(
            (np.asarray(results["faithful"].labels)
             == np.asarray(results["static"].labels)).all()
        )
        rows.append(
            (
                prob.name,
                round(times["faithful"], 4),
                round(times["static"], 4),
                round(times["faithful"] / times["static"], 2),
                same,
            )
        )
    return rows


def main() -> None:
    rows = run()
    print_csv(
        "faithful vs static DPP modes (identical labels required)",
        ["dataset", "faithful_s", "static_s", "speedup_x", "labels_identical"],
        rows,
    )
    assert all(r[-1] for r in rows), "modes must agree exactly"


if __name__ == "__main__":
    main()
