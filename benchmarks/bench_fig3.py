"""Paper Fig. 3 analogue: DPP-PMRF vs the coarse-parallel reference.

Fig. 3 plots OpenMP-runtime / DPP-runtime per dataset and concurrency.
Single-core container -> we report the concurrency-1 column: the ratio of
the coarse (outer-parallel-only, ragged-layout) formulation to the DPP
formulation, per dataset.  Bar > 1 means the DPP code is faster, matching
the paper's presentation.
"""

from __future__ import annotations

import jax

from benchmarks.common import build_problems, print_csv, time_fn
from repro.core.pmrf import em as em_mod
from repro.core.pmrf import reference


def run(size: int = 96, grid: int = 12) -> list:
    rows = []
    for prob in build_problems(size=size, grid=grid):
        hoods, model = prob.problem.hoods, prob.problem.model
        labels0 = jax.numpy.asarray(prob.labels0)
        mu0 = jax.numpy.asarray(prob.mu0)
        sigma0 = jax.numpy.asarray(prob.sigma0)

        ref = reference.coarse_em(hoods, model, prob.labels0, prob.mu0, prob.sigma0)
        t_ref = ref.seconds

        cfg = em_mod.EMConfig(mode="static")
        t_dpp = time_fn(
            lambda: em_mod.run_em(hoods, model, labels0, mu0, sigma0, cfg),
            repeats=3,
        )
        rows.append(
            (prob.name, round(t_ref, 4), round(t_dpp, 4), round(t_ref / t_dpp, 2))
        )
    return rows


def main() -> None:
    rows = run()
    print_csv(
        "fig3: coarse-parallel reference vs DPP-PMRF (ratio > 1 = DPP faster)",
        ["dataset", "reference_s", "dpp_s", "ratio"],
        rows,
    )


if __name__ == "__main__":
    main()
