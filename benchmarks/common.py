"""Shared benchmark utilities: problem construction + timing."""

from __future__ import annotations

import time

#: Set by ``benchmarks.run --check``: sections with regression gates turn
#: their reported comparisons into hard assertions (e.g. bench_pmrf fails
#: when the batch="auto" policy path is slower than the serial loop).
CHECK = False
from dataclasses import dataclass
from typing import Callable, Dict, List, Tuple

import jax
import numpy as np

from repro.core import synthetic
from repro.core.pmrf import em as em_mod
from repro.core.pmrf import pipeline


@dataclass
class Problem:
    name: str
    problem: pipeline.Problem
    labels0: np.ndarray
    mu0: np.ndarray
    sigma0: np.ndarray


def build_problems(
    *, size: int = 96, grid: int = 12, seed: int = 0
) -> List[Problem]:
    """One synthetic + one experimental-like slice, initialized identically
    for every engine under test (paper §4.1.1's two datasets)."""
    out = []
    sv = synthetic.make_synthetic_volume(seed=seed, n_slices=1, shape=(size, size))
    ev = synthetic.make_experimental_like_volume(
        seed=seed + 1, n_slices=1, shape=(size, size)
    )
    for name, vol in (("synthetic", sv), ("experimental", ev)):
        prob = pipeline.initialize(
            np.asarray(vol.images[0]), overseg_grid=(grid, grid)
        )
        labels0, mu0, sigma0 = em_mod.quantile_init(
            prob.graph.region_mean, prob.graph.n_regions
        )
        out.append(
            Problem(
                name=name,
                problem=prob,
                labels0=np.asarray(labels0),
                mu0=np.asarray(mu0),
                sigma0=np.asarray(sigma0),
            )
        )
    return out


def time_fn(fn: Callable[[], object], *, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds over ``repeats`` (after ``warmup`` calls)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def print_csv(title: str, header: List[str], rows: List[Tuple]) -> None:
    print(f"# {title}")
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    print()
