"""Deliverable (g): the roofline table, read from the dry-run artifacts.

Each row is one (arch x shape) cell on the single-pod 16x16 mesh: the
three roofline terms in seconds, the dominant bottleneck, MODEL_FLOPS
(6ND / 2ND), the useful-flops ratio, and the per-chip memory footprint.

Run the dry-run first:
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

from __future__ import annotations

import json
from pathlib import Path

ARTIFACTS = Path(__file__).parent / "artifacts" / "dryrun"


def _dir_for(variant: str) -> Path:
    if variant:
        d = ARTIFACTS.parent / f"dryrun_{variant}"
        if d.exists():
            return d
    return ARTIFACTS


def load_rows(mesh_tag: str = "pod16x16", variant: str = "") -> list:
    rows = []
    for path in sorted(_dir_for(variant).glob(f"*__{mesh_tag}.json")):
        d = json.loads(path.read_text())
        if d["status"] != "ok":
            rows.append(
                (d["arch"], d["shape"], d["status"], "", "", "", "", "", "", "", "")
            )
            continue
        r = d["roofline"]
        port = r.get("memory_portable_s", r["memory_s"])
        rows.append(
            (
                d["arch"],
                d["shape"],
                d["kind"],
                round(r["compute_s"] * 1e3, 2),
                round(port * 1e3, 2),
                # cap: a kernel never adds traffic over the portable path
                # (older artifacts predate the cap in launch/roofline.py)
                round(min(r["memory_s"], port) * 1e3, 2),
                round(r["collective_s"] * 1e3, 2),
                r["bound"],
                f'{r["model_flops"]:.2e}',
                round(r["useful_flops_ratio"], 3),
                round(d["memory"]["peak_bytes_estimate"] / 2**30, 2),
            )
        )
    return rows


def main() -> None:
    hdr = [
        "arch", "shape", "kind", "compute_ms", "memory_portable_ms",
        "memory_kernelized_ms", "collective_ms",
        "bound", "model_flops", "useful_ratio", "peak_GiB_per_chip",
    ]
    printed = False
    for variant, title in (
        ("baseline", "BASELINE (pre-hillclimb defaults)"),
        ("optimized", "OPTIMIZED (attention pin + dots_nb remat + microbatch-8 train)"),
        ("", "main artifacts"),
    ):
        rows = load_rows(variant=variant)
        if not rows or (variant == "" and printed):
            continue
        printed = True
        print(f"# roofline terms per (arch x shape), 16x16 single-pod mesh — {title}")
        print(",".join(hdr))
        for r in rows:
            print(",".join(str(x) for x in r))
        print()
    if not printed:
        print("no dry-run artifacts found — run repro.launch.dryrun first")


if __name__ == "__main__":
    main()
