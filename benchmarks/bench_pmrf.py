"""Per-mode EM optimization timing on the paper's configuration.

Runs the three execution modes (faithful / static / static-pallas) on a
fixed synthetic image built from ``configs/pmrf_paper.py`` and emits
``BENCH_pmrf.json`` so the perf trajectory of the MAP hot loop is tracked
across PRs.  Also reports the batched-vs-loop slice-stack timing through
the session API (``Segmenter.segment_stack``, DESIGN.md §9/§10) — the
forced-batch path AND the ``batch="auto"`` policy path, which ``--check``
gates (the cost-model-routed auto choice must stay within 10% of the
measured-best fixed config: on CPU that means routing around the
lockstep-batched inversion, whose root-cause fields under
``segment_volume`` quantify it; the model's decision is recorded under
``segment_volume.autotune``, DESIGN.md §18) — and a K-sweep
(K in {2, 3, 5, 8}) of the K-ary static AND fused static-pallas modes
(DESIGN.md §13/§16), with a ``--check`` gate holding the fused route's
per-EM-iteration cost flat in K (K=5 within 2.5x of K=2).
"""

from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import print_csv, time_fn
from repro import api
from repro.configs.pmrf_paper import CONFIG
from repro.core import synthetic
from repro.core.pmrf import em as em_mod
from repro.core.pmrf import pipeline
from repro.kernels import ops as kops

MODES = ("faithful", "static", "static-pallas")
K_SWEEP = (2, 3, 5, 8)
OUT_PATH = pathlib.Path("BENCH_pmrf.json")


def run() -> dict:
    shape = CONFIG.synthetic_shape
    vol = synthetic.make_synthetic_volume(
        seed=0, n_slices=CONFIG.synthetic_slices, shape=shape,
        gaussian_sigma=CONFIG.gaussian_sigma,
    )
    img = np.asarray(vol.images[0])
    prob = pipeline.initialize(img, overseg_grid=(16, 16), beta=CONFIG.beta)
    labels0, mu0, sigma0 = em_mod.quantile_init(
        prob.graph.region_mean, prob.graph.n_regions
    )
    labels0, mu0, sigma0 = jnp.asarray(labels0), jnp.asarray(mu0), jnp.asarray(sigma0)

    modes = {}
    base_labels = None
    for mode in MODES:
        cfg = em_mod.EMConfig(
            max_em_iters=CONFIG.max_em_iters, max_map_iters=CONFIG.max_map_iters,
            mode=mode, beta=CONFIG.beta, backend=CONFIG.backend,
        )
        t = time_fn(
            lambda cfg=cfg: em_mod.run_em(
                prob.hoods, prob.model, labels0, mu0, sigma0, cfg
            ),
            repeats=3,
        )
        res = em_mod.run_em(prob.hoods, prob.model, labels0, mu0, sigma0, cfg)
        labels = np.asarray(res.labels)
        if base_labels is None:
            base_labels = labels
        modes[mode] = {
            "optimize_seconds": round(t, 5),
            "em_iters": int(res.em_iters),
            "labels_match_faithful": bool((labels == base_labels).all()),
        }

    imgs = [np.asarray(im) for im in vol.images]
    sess = api.Segmenter(api.ExecutionConfig(overseg_grid=(16, 16)))
    res_loop, loop_s = sess.segment_stack(imgs, batch="never")
    res_batch, batch_s = sess.segment_stack(imgs, batch="always")
    _, auto_s = sess.segment_stack(imgs, batch="auto")
    # The cost-model decision behind batch="auto" (DESIGN.md §18): what
    # the autotuner predicted for each side, alongside what each side
    # measured above — the --check gate below holds the chosen side
    # within tolerance of the measured-best fixed config.
    autotune = sess.choose_batch([sess.plan(img) for img in imgs]).as_dict()

    # Root-cause instrumentation for the forced-batch inversion (batched
    # slower than the serial loop on CPU).  A vmapped lockstep while_loop
    # runs every lane until the SLOWEST slice converges — the inflation
    # factor below is exactly that padding work (B * max(iters) vs
    # sum(iters)); XLA:CPU then serializes the vmapped lanes, so the
    # inflation is paid in wall clock instead of being hidden by width.
    loop_iters = [int(r.em_iters) for r in res_loop]
    batch_iters = [int(r.em_iters) for r in res_batch]
    lockstep_inflation = (
        len(loop_iters) * max(loop_iters) / max(sum(loop_iters), 1)
    )
    segment_volume = {
        "slices": len(imgs),
        "loop_mean_optimize_seconds": round(loop_s, 5),
        "batched_mean_optimize_seconds": round(batch_s, 5),
        "auto_mean_optimize_seconds": round(auto_s, 5),
        "per_slice_em_iters": loop_iters,
        "batched_em_iters": batch_iters,
        "lockstep_inflation": round(lockstep_inflation, 4),
        "batched_over_loop": round(batch_s / max(loop_s, 1e-9), 4),
        "autotune": autotune,
        "note": (
            "forced batch='always' loses to the serial loop on CPU by "
            "design, not by defect: the vmapped lockstep while_loop runs "
            "every lane to the slowest slice's convergence "
            "(lockstep_inflation x the serial EM work) and XLA:CPU "
            "executes the vmapped lanes serially, so the padding work is "
            "pure wall-clock overhead.  batch='auto' routes around it via "
            "the calibrated cost model (DESIGN.md §18; decision recorded "
            "under 'autotune', gated below).  On accelerators the lanes "
            "run in parallel and the same inflation is hidden by hardware "
            "width."
        ),
    }

    # K-sweep: the K-ary modes on a K-phase volume of the same shape
    # (DESIGN.md §13/§16).  Tracks how the widened key spaces scale the
    # MAP hot loop — and whether the label-blocked fused tick keeps the
    # static-pallas per-EM-iteration cost flat in K (the --check gate).
    k_sweep = {"static": {}, "static-pallas": {}}
    for k in K_SWEEP:
        kvol = synthetic.make_kary_volume(
            seed=0, n_slices=1, shape=shape, n_phases=k
        )
        kprob = pipeline.initialize(
            np.asarray(kvol.images[0]), overseg_grid=(16, 16),
            beta=CONFIG.beta, n_labels=k,
        )
        kl0, km0, ks0 = em_mod.quantile_init(
            kprob.graph.region_mean, kprob.graph.n_regions, k
        )
        for mode in k_sweep:
            kcfg = em_mod.EMConfig(
                max_em_iters=CONFIG.max_em_iters,
                max_map_iters=CONFIG.max_map_iters,
                mode=mode, beta=CONFIG.beta, backend=CONFIG.backend,
            )
            t = time_fn(
                lambda kcfg=kcfg, kprob=kprob, kl0=kl0, km0=km0, ks0=ks0:
                    em_mod.run_em(kprob.hoods, kprob.model, kl0, km0, ks0, kcfg),
                repeats=3,
            )
            res = em_mod.run_em(kprob.hoods, kprob.model, kl0, km0, ks0, kcfg)
            em_iters = int(res.em_iters)
            k_sweep[mode][str(k)] = {
                "optimize_seconds": round(t, 5),
                "em_iters": em_iters,
                "per_em_iter_seconds": round(t / max(em_iters, 1), 6),
                "labels_in_use": int(
                    len(np.unique(np.asarray(res.labels)[: kprob.graph.n_regions]))
                ),
            }

    return {
        "config": CONFIG.name,
        "image_shape": list(shape),
        "n_regions": prob.graph.n_regions,
        "n_hoods": prob.hoods.n_hoods,
        "backend": kops.resolve_backend(CONFIG.backend),
        "jax_backend": jax.default_backend(),
        "modes": modes,
        "segment_volume": segment_volume,
        "k_sweep": k_sweep,
    }


def main() -> None:
    result = run()
    OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")
    rows = [
        (m, d["optimize_seconds"], d["em_iters"], d["labels_match_faithful"])
        for m, d in result["modes"].items()
    ]
    print_csv(
        f"PMRF per-mode optimize seconds ({result['config']}, "
        f"backend={result['backend']}) -> {OUT_PATH}",
        ["mode", "optimize_s", "em_iters", "labels_match_faithful"],
        rows,
    )
    sv = result["segment_volume"]
    print_csv(
        "segment_volume loop vs batched vs auto (mean optimize seconds/slice)",
        ["slices", "loop_s", "batched_s", "auto_s"],
        [(sv["slices"], sv["loop_mean_optimize_seconds"],
          sv["batched_mean_optimize_seconds"], sv["auto_mean_optimize_seconds"])],
    )
    ks = result["k_sweep"]
    print_csv(
        "K-sweep: K-ary per-mode optimize seconds (DESIGN.md §13/§16)",
        ["mode", "K", "optimize_s", "per_em_iter_s", "em_iters", "labels_in_use"],
        [(mode, k, d["optimize_seconds"], d["per_em_iter_seconds"],
          d["em_iters"], d["labels_in_use"])
         for mode, sweep in ks.items() for k, d in sweep.items()],
    )
    # Exact cross-mode label equality is only claimed on the XLA/CPU path
    # (energy.py); on TPU the one-hot dot accumulation order can perturb
    # hood energies at the last bit and shift convergence — report there,
    # enforce here.
    if result["backend"] == "xla":
        assert all(d["labels_match_faithful"] for d in result["modes"].values())
    if common.CHECK:
        # The autotuner gate (`benchmarks/run.py --check`, DESIGN.md §18):
        # batch="auto" routes on the calibrated cost model, and its choice
        # must land within 10% of the measured-best FIXED config — on CPU
        # that means routing around the lockstep inversion (batched loses
        # ~1.8x to the loop); on accelerators the same bound asserts the
        # model flips to the batched side where it measures faster.
        loop_s, batch_s, auto_s = (
            sv["loop_mean_optimize_seconds"],
            sv["batched_mean_optimize_seconds"],
            sv["auto_mean_optimize_seconds"],
        )
        best_s = min(loop_s, batch_s)
        assert auto_s <= best_s * 1.10, (
            f"segment_stack(batch='auto') regressed: auto {auto_s}s vs best "
            f"fixed config {best_s}s (loop {loop_s}s / batched {batch_s}s) — "
            f"the autotuned plan must stay within 10% of the best fixed "
            f"config (decision: {sv['autotune']})"
        )
        assert all(
            d["labels_in_use"] == int(k)
            for sweep in ks.values() for k, d in sweep.items()
        ), "K-sweep: some label never captured a region — K-ary EM degenerated"
        # The fused-tick K-flatness gate (DESIGN.md §16): label-blocked
        # tiles + compound-key reductions make the per-EM-iteration cost of
        # the fused static-pallas route scale sub-linearly in K — K=5 must
        # stay within 2.5x of K=2 per iteration (a label-replicated layout
        # would pay ~2.5x in kernel work alone, plus per-K launch overhead).
        sp = ks["static-pallas"]
        k_ratio = sp["5"]["per_em_iter_seconds"] / sp["2"]["per_em_iter_seconds"]
        assert k_ratio <= 2.5, (
            f"fused-tick K-sweep regressed: static-pallas per-EM-iter "
            f"K=5/K=2 ratio {k_ratio:.2f} > 2.5"
        )


if __name__ == "__main__":
    main()
