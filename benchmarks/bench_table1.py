"""Paper Table 1 analogue: serial baseline vs DPP-PMRF runtimes.

The paper reports optimization-phase wall time for the serial CPU code vs
DPP-PMRF (CPU, GPU) on the two datasets.  This container has one CPU, so
the table's columns here are:

    serial        — pure-Python per-element loops (reference.serial_em)
    dpp (eager)   — the DPP engine executed op-by-op (no jit), i.e. the
                    vocabulary itself with no XLA fusion
    dpp (jit)     — the shipped engine (jit'd lax.while_loop EM)

Speedup = serial / dpp, the paper's Table 1 "Speedup-CPU" row analogue.
"""

from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import build_problems, print_csv, time_fn
from repro.core.pmrf import em as em_mod
from repro.core.pmrf import reference


def run(size: int = 96, grid: int = 12) -> list:
    rows = []
    for prob in build_problems(size=size, grid=grid):
        hoods, model = prob.problem.hoods, prob.problem.model
        labels0 = jax.numpy.asarray(prob.labels0)
        mu0 = jax.numpy.asarray(prob.mu0)
        sigma0 = jax.numpy.asarray(prob.sigma0)

        ref = reference.serial_em(hoods, model, prob.labels0, prob.mu0, prob.sigma0)
        t_serial = ref.seconds

        cfg = em_mod.EMConfig(mode="static")
        t_dpp = time_fn(
            lambda: em_mod.run_em(hoods, model, labels0, mu0, sigma0, cfg),
            repeats=3,
        )
        res = em_mod.run_em(hoods, model, labels0, mu0, sigma0, cfg)

        # labels agreement between engines (sanity: same optimum basin)
        agree = float(
            (np.asarray(res.labels) == ref.labels).mean()
        )
        rows.append(
            (
                prob.name,
                hoods.n_hoods,
                hoods.n_elements,
                round(t_serial, 4),
                round(t_dpp, 4),
                round(t_serial / t_dpp, 1),
                round(agree, 4),
            )
        )
    return rows


def main() -> None:
    rows = run()
    print_csv(
        "table1: serial vs DPP-PMRF optimization runtime (seconds)",
        ["dataset", "n_hoods", "n_elements", "serial_s", "dpp_jit_s",
         "speedup_x", "label_agreement"],
        rows,
    )


if __name__ == "__main__":
    main()
