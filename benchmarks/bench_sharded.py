"""Sharded-vs-single-device EM timing through the session API.

Measures the same problem at ``shards=1`` and ``shards=8`` for the two
optimized execution modes (static, static-pallas) and emits
``BENCH_sharded.json`` for cross-PR perf tracking of the multi-device
path (DESIGN.md §11).  Also asserts the sharded segmentation is
bit-identical to the single-device one — the benchmark doubles as a
cheap end-to-end parity check.

The XLA device count is process-global and fixed at backend init, so the
measurement runs in a child process launched with
``--xla_force_host_platform_device_count=8`` (a no-op for real
accelerator platforms: the flag only affects *host* devices); the parent
forwards the child's JSON.  On CPU the 8 "devices" share the machine's
cores, so the sharded timings measure collective/partitioning overhead,
not speedup — the number to watch off-TPU is the overhead ratio.

The size sweep also records the calibrated cost model's shard choice per
size (``--shards auto``, DESIGN.md §18); under ``--check`` the parent
gates that the chosen shard count's measured time stays within 10% of
the best fixed shard count in every cell of the sweep.
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

OUT_PATH = pathlib.Path("BENCH_sharded.json")
MODES = ("static", "static-pallas")
SHARDS = (1, 8)
#: Square image edge lengths for the size sweep.  The base size keeps the
#: historical BENCH_sharded numbers comparable; the larger sizes track how
#: partitioning overhead amortizes as the per-shard work grows.  The
#: oversegmentation grid scales with the image (one cell per 8x8 tile) so
#: every size runs at the same region granularity.
BASE_SIZE = 96
SIZES = (96, 192, 288)


def _grid(size: int):
    return (size // 8, size // 8)


def _measure() -> dict:
    import jax
    import numpy as np

    from benchmarks.common import time_fn
    from repro import api
    from repro.core import synthetic

    def image(size):
        vol = synthetic.make_synthetic_volume(
            seed=0, n_slices=1, shape=(size, size)
        )
        return np.asarray(vol.images[0])

    def sweep(img, size, modes):
        """mode x shards timing at one size, with the parity assert."""
        per_mode = {}
        for mode in modes:
            per = {}
            segmentations = {}
            for shards in SHARDS:
                sess = api.Segmenter(
                    api.ExecutionConfig(
                        overseg_grid=_grid(size), mode=mode, shards=shards
                    )
                )
                plan = sess.plan(img)
                exe = sess.compile(plan)  # pay the compile outside the timer
                res = sess.execute(plan, seed=0)
                segmentations[shards] = np.asarray(res.segmentation)
                t = time_fn(lambda: sess.execute(plan, seed=0), repeats=3)
                per[str(shards)] = {
                    "optimize_seconds": round(t, 5),
                    "compile_seconds": round(exe.compile_seconds, 3),
                    "em_iters": int(res.em_iters),
                }
                if shards == 1:
                    # The cost-model shard routing for this size
                    # (--shards auto, DESIGN.md §18); the parent's
                    # --check gate holds the chosen count within 10% of
                    # the measured-best fixed count.
                    per["autotune"] = sess.cost_model().choose_shards(
                        mode=mode, bucket=plan.bucket, candidates=SHARDS,
                        max_em_iters=sess.config.max_em_iters,
                        max_map_iters=sess.config.max_map_iters,
                    ).as_dict()
            match = bool(
                (segmentations[min(SHARDS)] == segmentations[max(SHARDS)]).all()
            )
            per["labels_match"] = match
            assert match, (
                f"sharded {mode} segmentation diverged from single-device "
                f"at size {size}"
            )
            per_mode[mode] = per
        return per_mode

    out = {
        "jax_backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "image_shape": [BASE_SIZE, BASE_SIZE],
        "modes": sweep(image(BASE_SIZE), BASE_SIZE, MODES),
        # Size sweep on the fused static-pallas route only: it is the
        # serving-path mode, and the static row at BASE_SIZE above already
        # anchors the cross-mode comparison.
        "sizes": {
            str(size): {
                "overseg_grid": list(_grid(size)),
                **sweep(image(size), size, ("static-pallas",))["static-pallas"],
            }
            for size in SIZES
        },
    }
    return out


def main() -> None:
    if "--child" in sys.argv:
        print(json.dumps(_measure()))
        return

    # jax stays unimported in the parent; repro.xla_env imports nothing heavy
    from repro.xla_env import force_host_device_count

    root = pathlib.Path(__file__).resolve().parent.parent
    env = force_host_device_count(max(SHARDS), dict(os.environ))
    env["PYTHONPATH"] = str(root / "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sharded", "--child"],
        capture_output=True, text=True, env=env, cwd=root, timeout=1800,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded bench child failed:\n{proc.stdout}\n{proc.stderr}"
        )
    result = json.loads(proc.stdout.splitlines()[-1])
    OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")

    from benchmarks.common import print_csv

    rows = []
    for mode, per in result["modes"].items():
        for shards in map(str, SHARDS):
            d = per[shards]
            rows.append((mode, shards, d["optimize_seconds"],
                         d["compile_seconds"], per["labels_match"]))
    print_csv(
        f"sharded EM: 1 vs {max(SHARDS)} shards "
        f"({result['jax_backend']}, {result['device_count']} devices) -> {OUT_PATH}",
        ["mode", "shards", "optimize_s", "compile_s", "labels_match"],
        rows,
    )
    size_rows = []
    for size, per in result["sizes"].items():
        for shards in map(str, SHARDS):
            d = per[shards]
            size_rows.append((size, shards, d["optimize_seconds"],
                              d["em_iters"], per["labels_match"]))
    print_csv(
        "sharded EM size sweep (static-pallas)",
        ["size", "shards", "optimize_s", "em_iters", "labels_match"],
        size_rows,
    )

    from benchmarks import common

    if common.CHECK:
        # The shard-autotuner gate (DESIGN.md §18): at every size in the
        # sweep the cost model's chosen shard count must measure within
        # 10% of the best fixed shard count — the model is allowed to be
        # wrong about absolute seconds, not about the ranking.
        for size, per in result["sizes"].items():
            chosen = per["autotune"]["shards"]
            measured = {s: per[str(s)]["optimize_seconds"] for s in SHARDS}
            best = min(measured.values())
            assert measured[chosen] <= best * 1.10, (
                f"shard autotuner regressed at size {size}: chose "
                f"{chosen} shards ({measured[chosen]}s) vs best fixed "
                f"{best}s (measured {measured}; decision {per['autotune']})"
            )


if __name__ == "__main__":
    main()
