"""Session-API latency: cold compile vs warm cache, batched vs serial.

Exercises the three-phase ``Segmenter`` lifecycle (DESIGN.md §10) on the
paper's synthetic data and emits ``BENCH_api.json``:

* ``cold_compile_seconds``   — first `compile()` for a fresh bucket (AOT
  lower + XLA compile; what a cache miss costs).
* ``warm_execute_seconds``   — `execute()` against the cached executable
  (what steady-state traffic pays).
* ``serial_8_seconds`` / ``batched_8_seconds`` — 8 concurrent same-bucket
  requests run as 8 warm `execute()` calls vs one `submit()`/`drain()`
  micro-batched launch (both exclude their compile, which is reported
  separately), plus the implied per-request throughput ratio.

On CPU the batched ratio is typically < 1: a vmapped ``while_loop`` runs
until the *slowest* element converges and XLA:CPU serializes the batch
lanes, so coalescing only pays off on accelerators (where it replaces 8
kernel-launch streams with one) — track the number, don't assert on it.
"""

from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from benchmarks.common import print_csv, time_fn
from repro import api
from repro.core import synthetic
from repro.core.pmrf import em as em_mod

OUT_PATH = pathlib.Path("BENCH_api.json")
N_CONCURRENT = 8


def run() -> dict:
    vol = synthetic.make_synthetic_volume(
        seed=0, n_slices=N_CONCURRENT, shape=(96, 96)
    )
    imgs = [np.asarray(im) for im in vol.images]

    jax.clear_caches()
    api.reset_sessions()
    em_mod.reset_trace_counts()  # report this section's traces, not history
    cfg = api.ExecutionConfig(overseg_grid=(12, 12), capacity_bucket=4096)
    sess = api.Segmenter(cfg)
    plans = [sess.plan(img) for img in imgs]
    bucket = plans[0].bucket
    same_bucket = all(p.bucket == bucket for p in plans)

    # Cold compile (the cache-miss cost) ...
    t0 = time.perf_counter()
    exe = sess.compile(bucket)
    cold_s = time.perf_counter() - t0
    assert exe.compile_seconds <= cold_s

    # ... vs warm execute (steady-state per-request latency).
    warm_s = time_fn(lambda: sess.execute(plans[0]).segmentation, repeats=3)
    assert sess.stats.misses == 1, "warm executes must all hit the cache"

    # 8 concurrent same-bucket requests: serial vs micro-batched.
    serial_s = time_fn(
        lambda: [sess.execute(p) for p in plans], repeats=3
    )
    # Pre-compile the batch executable so the batched timing is also warm.
    sess.compile(bucket, batch=N_CONCURRENT)

    last_results = []

    def batched():
        for p in plans:
            sess.submit(p, bucket=bucket)
        last_results[:] = sess.drain()
        return last_results

    batched_s = time_fn(batched, repeats=3)
    results = last_results

    return {
        "bucket": list(bucket),
        "same_bucket": bool(same_bucket),
        "backend": cfg.resolved_backend(),
        "jax_backend": jax.default_backend(),
        "n_concurrent": N_CONCURRENT,
        "cold_compile_seconds": round(cold_s, 5),
        "warm_execute_seconds": round(warm_s, 5),
        "compile_amortization_x": round(cold_s / max(warm_s, 1e-9), 2),
        "serial_8_seconds": round(serial_s, 5),
        "batched_8_seconds": round(batched_s, 5),
        "batched_speedup_x": round(serial_s / max(batched_s, 1e-9), 2),
        "cache": sess.stats.as_dict(),
        "trace_counts": dict(em_mod.TRACE_COUNTS),
        "mean_em_iters": float(np.mean([r.em_iters for r in results])),
    }


def main() -> None:
    result = run()
    OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")
    print_csv(
        f"session API: cold vs warm, serial vs batched -> {OUT_PATH}",
        ["cold_compile_s", "warm_execute_s", "serial_8_s", "batched_8_s",
         "batched_speedup_x"],
        [(result["cold_compile_seconds"], result["warm_execute_seconds"],
          result["serial_8_seconds"], result["batched_8_seconds"],
          result["batched_speedup_x"])],
    )
    assert result["same_bucket"], "bench premise: all slices share one bucket"
    assert result["cache"]["hits"] > 0 and result["cache"]["evictions"] == 0


if __name__ == "__main__":
    main()
