"""Benchmark aggregator: ``PYTHONPATH=src python -m benchmarks.run``.

One section per paper table/figure + the framework benches:

    table1              serial vs DPP-PMRF runtime (paper Table 1)
    fig3                coarse-parallel reference vs DPP (paper Fig. 3)
    fig4                per-DPP breakdown + size scaling (paper Fig. 4)
    faithful_vs_static  beyond-paper sort-hoisting ablation
    pmrf                per-mode EM timing on the paper config; emits
                        BENCH_pmrf.json for cross-PR perf tracking
    api                 session API: cold-compile vs warm-cache latency and
                        batched vs serial throughput; emits BENCH_api.json
    sharded             multi-device EM: 1 vs 8 shards, static and
                        static-pallas; emits BENCH_sharded.json
    serve               serving engine: serial vs lockstep-batched vs
                        continuous ticked batching; emits BENCH_serve.json
    kernels             Pallas kernels vs jnp oracles
    roofline            (arch x shape) roofline table from the dry-run

Pass section names to run a subset: ``python -m benchmarks.run table1 fig3``.

``--check`` turns each section's regression gates into hard assertions
(``benchmarks.common.CHECK``): a gated comparison that regresses — e.g. the
``segment_volume`` batch="auto" path running slower than the serial loop
(bench_pmrf) — fails the run instead of only being reported.
"""

from __future__ import annotations

import sys
import time
import traceback

SECTIONS = (
    "table1", "fig3", "fig4", "faithful_vs_static", "pmrf", "api", "sharded",
    "serve", "kernels", "roofline",
)


def main() -> None:
    args = sys.argv[1:]
    if "--check" in args:
        from benchmarks import common

        common.CHECK = True
        args = [a for a in args if a != "--check"]
    want = args or list(SECTIONS)
    failures = []
    for name in want:
        assert name in SECTIONS, f"unknown section {name!r}; have {SECTIONS}"
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["main"])
        print(f"===== {name} =====")
        t0 = time.perf_counter()
        try:
            mod.main()
        except Exception:
            failures.append(name)
            traceback.print_exc()
        print(f"===== {name} done in {time.perf_counter()-t0:.1f}s =====\n")
    if failures:
        raise SystemExit(f"benchmark sections failed: {failures}")


if __name__ == "__main__":
    main()
