"""Benchmark aggregator: ``PYTHONPATH=src python -m benchmarks.run``.

One section per paper table/figure + the framework benches:

    table1              serial vs DPP-PMRF runtime (paper Table 1)
    fig3                coarse-parallel reference vs DPP (paper Fig. 3)
    fig4                per-DPP breakdown + size scaling (paper Fig. 4)
    faithful_vs_static  beyond-paper sort-hoisting ablation
    pmrf                per-mode EM timing on the paper config; emits
                        BENCH_pmrf.json for cross-PR perf tracking
    api                 session API: cold-compile vs warm-cache latency and
                        batched vs serial throughput; emits BENCH_api.json
    sharded             multi-device EM: 1 vs 8 shards, static and
                        static-pallas; emits BENCH_sharded.json
    serve               serving engine: serial vs lockstep-batched vs
                        continuous ticked batching; emits BENCH_serve.json
    kernels             Pallas kernels vs jnp oracles
    roofline            (arch x shape) roofline table from the dry-run

Pass section names to run a subset: ``python -m benchmarks.run table1 fig3``.

``--check`` turns each section's regression gates into hard assertions
(``benchmarks.common.CHECK``): a gated comparison that regresses — e.g. the
autotuned ``segment_volume`` batch="auto" plan losing to the best fixed
config by more than 10% (bench_pmrf), or the ``--shards auto`` choice
losing a cell of the sharded size sweep (bench_sharded) — fails the run
instead of only being reported.  ``--check`` also runs the
calibration-table drift gate: the checked-in
``src/repro/planning/calibration.json`` must refit byte-identically from
its own stored observations (DESIGN.md §18).
"""

from __future__ import annotations

import sys
import time
import traceback

SECTIONS = (
    "table1", "fig3", "fig4", "faithful_vs_static", "pmrf", "api", "sharded",
    "serve", "kernels", "roofline",
)


def _check_calibration_drift() -> None:
    """The drift gate (DESIGN.md §18): the checked-in calibration table is
    a pure function of its own stored observations, so refitting must
    reproduce the file byte-for-byte.  Drift means a stale fit or a hand
    edit — the autotuner gates above would be vouching for a table nobody
    can regenerate."""
    from repro.planning import costmodel as planning

    table = planning.load_table()
    refit = planning.fit_table(table["observations"], table["meta"])
    if planning.table_to_json(refit) != planning.default_table_path().read_text():
        raise AssertionError(
            "calibration-table drift: src/repro/planning/calibration.json "
            "does not refit from its own stored observations; regenerate "
            "with PYTHONPATH=src python -m repro.planning.calibrate --refit"
        )
    print("calibration table: refit reproduces the checked-in bytes")


def main() -> None:
    args = sys.argv[1:]
    if "--check" in args:
        from benchmarks import common

        common.CHECK = True
        args = [a for a in args if a != "--check"]
    want = args or list(SECTIONS)
    failures = []
    for name in want:
        assert name in SECTIONS, f"unknown section {name!r}; have {SECTIONS}"
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["main"])
        print(f"===== {name} =====")
        t0 = time.perf_counter()
        try:
            mod.main()
        except Exception:
            failures.append(name)
            traceback.print_exc()
        print(f"===== {name} done in {time.perf_counter()-t0:.1f}s =====\n")
    from benchmarks import common

    if common.CHECK:
        print("===== calibration drift gate =====")
        try:
            _check_calibration_drift()
        except Exception:
            failures.append("calibration-drift")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark sections failed: {failures}")


if __name__ == "__main__":
    main()
