"""Serving-engine latency/throughput: serial vs lockstep vs continuous,
plus a paced-arrival latency-SLO sweep (DESIGN.md §17).

The workload is the exact case that produced BENCH_api.json's
``batched_speedup_x: 0.45`` inversion: a stream of same-bucket requests
with deliberately mixed convergence iteration counts.  Three ways to serve
it, all through one warm session (compiles excluded from every timing):

* ``serial``       — one warm ``execute()`` per request; each request pays
                     exactly its own iterations, plus per-request dispatch.
* ``lockstep``     — ``submit()``/``drain()`` micro-batching in groups of
                     ``SLOTS``: one vmapped ``run_em_batched`` launch per
                     group, so every lane pays the *slowest* lane's (EM-
                     and MAP-level) iteration count.
* ``continuous``   — the ticked serving engine (DESIGN.md §12/§17):
                     ``SLOTS`` slots, adaptive ``tick_iters="auto"``,
                     converged lanes retired at the next tick boundary (the
                     driver exits a tick early once the whole pool is done).

``SLOTS`` is 4: pool width should track the machine's actual parallelism,
and the bench host is a single core, so a pool micro-step costs ~width x
a serial step.  Measured here, width 4 matches width 8 on batch-dump
throughput (~16 rps both) while halving a lone request's residence
(0.21s vs 0.41s) — extra width a single core can't execute buys nothing
but latency (DESIGN.md §17).

Single-point numbers lie about serving (that is how the 0.67x regression
shipped behind a "1.15x" headline), so the continuous path is also
measured under **paced arrivals**: requests arrive at a fixed offered
rate expressed as a multiple of the measured serial throughput, and the
engine reports ``queue_s`` (waiting for a slot) and ``residence_s``
(resident in a lane) separately.  The emitted ``slo_curve`` gives, per
latency budget (a multiple of serial p50), the highest offered load whose
attained p95 stays within it — a curve, not a point.

Gates (hard assertions under ``benchmarks.run --check``):

* continuous batch-dump throughput >= 1.0x serial;
* continuous latency p50 under light paced load (lowest offered
  multiple) <= 5x serial p50.

Always asserted, check-mode or not: per-request labels bit-identical to
serial ``run_em``, and healthy-lane throughput retention >= 90% under 5%
poisoned requests (the fault-tolerance PR's target, DESIGN.md §14).
"""

from __future__ import annotations

import contextlib
import json
import pathlib
import time

import jax
import numpy as np

from benchmarks import common
from benchmarks.common import print_csv
from repro import api
from repro.core import synthetic
from repro.core.pmrf import em as em_mod
from repro.serving import SegmentationEngine
from repro.serving.engine import DEFAULT_TICK_LADDER
from repro.testing import chaos as chaos_mod

OUT_PATH = pathlib.Path("BENCH_serve.json")
N_REQUESTS = 24
SLOTS = 4
SHAPE = (96, 96)
GRID = (12, 12)
POISON_RATES = (0.05, 0.20)
#: Paced-arrival offered loads, as multiples of measured serial throughput.
OFFERED_MULTIPLES = (0.6, 0.9, 1.2)
#: Latency budgets for the SLO curve, as multiples of serial p50.
SLO_MULTIPLES = (2.0, 5.0, 10.0)


def _percentiles(lat, prefix="latency"):
    lat = np.asarray(lat, np.float64)
    return {
        f"{prefix}_p50_s": round(float(np.percentile(lat, 50)), 5),
        f"{prefix}_p95_s": round(float(np.percentile(lat, 95)), 5),
    }


def _latency_block(completions):
    """Honest three-way latency accounting (DESIGN.md §17): queue and
    residence reported separately, never folded into one number."""
    out = {}
    out.update(_percentiles([c.latency_s for c in completions], "latency"))
    out.update(_percentiles([c.queue_s for c in completions], "queue"))
    out.update(_percentiles([c.residence_s for c in completions], "residence"))
    return out


def _paced_run(sess, plans, bucket, offered_rps):
    """Drive one adaptive engine with requests arriving every
    ``1/offered_rps`` seconds; returns (completions, stats, attained_rps).

    The engine ticks whenever it has live work and sleeps until the next
    arrival otherwise, so queue time is a function of offered load, not of
    the driver loop's politeness.
    """
    eng = SegmentationEngine(
        sess, max_batch=SLOTS, tick_iters="auto", bucket=bucket
    )
    interval = 1.0 / offered_rps
    nxt = 0
    t0 = time.perf_counter()
    while nxt < len(plans) or eng.pending() or eng.active():
        now = time.perf_counter() - t0
        while nxt < len(plans) and nxt * interval <= now:
            eng.submit(plans[nxt], rid=nxt)
            nxt += 1
        if eng.pending() or eng.active():
            eng.step()
        elif nxt < len(plans):
            time.sleep(max(0.0, nxt * interval - (time.perf_counter() - t0)))
    wall = time.perf_counter() - t0
    comps = eng.run()   # pool already drained; collects the completions
    return comps, eng.stats(), len(comps) / wall


def run() -> dict:
    jax.clear_caches()
    api.reset_sessions()
    em_mod.reset_trace_counts()

    cfg = api.ExecutionConfig(overseg_grid=GRID, capacity_bucket=4096)
    sess = api.Segmenter(cfg)
    vol = synthetic.make_synthetic_volume(
        seed=0, n_slices=N_REQUESTS, shape=SHAPE
    )
    plans = [sess.plan(np.asarray(im)) for im in vol.images]
    bucket = api.BucketKey(*(max(p.bucket[d] for p in plans) for d in range(3)))

    # Warm every executable + padding memo up front: this bench measures
    # steady-state serving, compiles are BENCH_api.json's subject.  The
    # adaptive engine switches between ladder sizes, so the whole ladder
    # is warmed (the engine would also compile it at pool bring-up, but
    # that would land inside the timed region).
    sess.compile(bucket)
    sess.compile(bucket, batch=SLOTS)
    for t in DEFAULT_TICK_LADDER:
        sess.compile_ticked(bucket, batch=SLOTS, tick_iters=t)
    serial_results = [
        sess.execute(p, bucket=bucket) for p in plans
    ]  # also warms _pad_plan memos
    for p in plans:
        sess.lane_state(p, bucket=bucket)  # admission memos (§17)
    # A throwaway pool drive compiles the engine-layer host jits
    # (_write_pools / _read_lane / _mark_done / ...) — once-per-process
    # costs that would otherwise land inside the continuous timing.
    warm_eng = SegmentationEngine(
        sess, max_batch=SLOTS, tick_iters="auto", bucket=bucket
    )
    for rid, p in enumerate(plans[:2]):
        warm_eng.submit(p, rid=rid)
    warm_eng.run()

    # -- serial: per-request latency is each request's own execute. -------
    t0 = time.perf_counter()
    lat_serial = []
    for p in plans:
        t1 = time.perf_counter()
        sess.execute(p, bucket=bucket)
        lat_serial.append(time.perf_counter() - t1)
    serial_wall = time.perf_counter() - t0
    serial_rps = N_REQUESTS / serial_wall
    serial_p50 = float(np.percentile(lat_serial, 50))

    # -- lockstep: groups of SLOTS through one vmapped launch each. -------
    t0 = time.perf_counter()
    lat_lockstep = []
    for start in range(0, N_REQUESTS, SLOTS):
        group = plans[start:start + SLOTS]
        t1 = time.perf_counter()
        for p in group:
            sess.submit(p, bucket=bucket)
        sess.drain()
        lat_lockstep.extend([time.perf_counter() - t1] * len(group))
    lockstep_wall = time.perf_counter() - t0

    # -- continuous batch-dump: all 24 submitted at t=0 (the saturation/
    # throughput view; queue_s dominates latency here by construction). ---
    engine = SegmentationEngine(
        sess, max_batch=SLOTS, tick_iters="auto", bucket=bucket
    )
    t0 = time.perf_counter()
    for rid, p in enumerate(plans):
        engine.submit(p, rid=rid)
    completions = engine.run()
    continuous_wall = time.perf_counter() - t0

    # Per-request label bit-identity vs serial run_em (the §12 contract).
    identical = all(
        np.array_equal(c.result.region_labels, serial_results[c.rid].region_labels)
        and np.array_equal(c.result.mu, serial_results[c.rid].mu)
        and c.result.em_iters == serial_results[c.rid].em_iters
        for c in completions
    )

    # -- paced-arrival SLO sweep: offered load as multiples of serial. -----
    paced = {}
    for mult in OFFERED_MULTIPLES:
        comps, st, attained = _paced_run(sess, plans, bucket, mult * serial_rps)
        paced[f"offered_{mult}x"] = {
            "offered_rps": round(mult * serial_rps, 3),
            "attained_rps": round(attained, 3),
            **_latency_block(comps),
            "final_tick_iters": st["tick_iters"],
            "tick_switches": st["tick_switches"],
            "steps_saved_early_exit": st["steps_saved_early_exit"],
        }
    # Attained throughput at p95 < X * serial_p50: the highest offered
    # load whose measured p95 stays inside each latency budget.
    slo_curve = {}
    for x in SLO_MULTIPLES:
        ok = [
            row["attained_rps"]
            for row in paced.values()
            if row["latency_p95_s"] < x * serial_p50
        ]
        slo_curve[f"p95_lt_{x}x_serial_p50"] = round(max(ok), 3) if ok else 0.0

    # -- fault-rate sweep: healthy-lane throughput retention. --------------
    # 5% / 20% poison deterministic rids with the bad_init fault (NaN mu0
    # -> quarantined as `diverged` at the first EM boundary).  Retention
    # compares healthy completions/sec against a clean drive measured
    # inside the sweep — each point is best-of-2 fresh engine drives, so
    # the baseline and the fault runs see the same adaptive-policy warmth
    # and the ratio isn't polluted by single-run scheduler variance.
    def _fault_drive(rids):
        eng = SegmentationEngine(
            sess, max_batch=SLOTS, tick_iters="auto", bucket=bucket
        )
        ctx = (
            chaos_mod.inject(chaos_mod.ChaosConfig(seed=1, bad_init_rids=rids))
            if rids
            else contextlib.nullcontext()
        )
        with ctx:
            t0 = time.perf_counter()
            for rid, p in enumerate(plans):
                eng.submit(p, rid=rid)
            comps = eng.run()
            return comps, time.perf_counter() - t0

    def _best_of_2(rids):
        comps, wall = _fault_drive(rids)
        comps2, wall2 = _fault_drive(rids)
        return (comps2, wall2) if wall2 < wall else (comps, wall)

    _, clean_wall = _best_of_2(())
    clean_rps = N_REQUESTS / clean_wall
    fault_sweep = {
        "poison_0pct": {
            "poisoned_rids": [],
            "wall_s": round(clean_wall, 4),
            "healthy_rps": round(clean_rps, 3),
            "healthy_retention": 1.0,
        }
    }
    for rate in POISON_RATES:
        k = max(1, round(N_REQUESTS * rate))
        rids = tuple(range(0, N_REQUESTS, max(1, N_REQUESTS // k)))[:k]
        comps, wall = _best_of_2(rids)
        healthy = [c for c in comps if c.rid not in rids]
        quarantined = [c for c in comps if c.rid in rids]
        healthy_rps = len(healthy) / wall
        fault_sweep[f"poison_{round(rate * 100)}pct"] = {
            "poisoned_rids": list(rids),
            "wall_s": round(wall, 4),
            "healthy_rps": round(healthy_rps, 3),
            "healthy_retention": round(healthy_rps / clean_rps, 3),
            "quarantined": sum(1 for c in quarantined if c.status == "diverged"),
            "healthy_identical_to_serial": all(
                np.array_equal(
                    c.result.region_labels, serial_results[c.rid].region_labels
                )
                for c in healthy
            ),
        }

    em_iters = [r.em_iters for r in serial_results]
    return {
        "n_requests": N_REQUESTS,
        "slots": SLOTS,
        "tick_policy": "auto",
        "tick_ladder": list(DEFAULT_TICK_LADDER),
        "bucket": list(bucket),
        "backend": cfg.resolved_backend(),
        "jax_backend": jax.default_backend(),
        "em_iters_min_mean_max": [
            int(min(em_iters)),
            round(float(np.mean(em_iters)), 2),
            int(max(em_iters)),
        ],
        "serial": {
            "wall_s": round(serial_wall, 4),
            "throughput_rps": round(serial_rps, 3),
            **_percentiles(lat_serial),
        },
        "lockstep": {
            "wall_s": round(lockstep_wall, 4),
            "throughput_rps": round(N_REQUESTS / lockstep_wall, 3),
            **_percentiles(lat_lockstep),
        },
        "continuous": {
            "wall_s": round(continuous_wall, 4),
            "throughput_rps": round(N_REQUESTS / continuous_wall, 3),
            **_latency_block(completions),
            "engine": engine.stats(),
        },
        "paced": paced,
        "slo_curve": slo_curve,
        "lockstep_vs_serial_x": round(serial_wall / lockstep_wall, 2),
        "continuous_vs_serial_x": round(serial_wall / continuous_wall, 2),
        "labels_identical_to_serial": bool(identical),
        "fault_sweep": fault_sweep,
        "trace_counts": dict(em_mod.TRACE_COUNTS),
    }


def main() -> None:
    result = run()
    OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")
    print_csv(
        f"serving: serial vs lockstep vs continuous -> {OUT_PATH}",
        ["serial_s", "lockstep_s", "continuous_s", "lockstep_x",
         "continuous_x", "identical"],
        [(result["serial"]["wall_s"], result["lockstep"]["wall_s"],
          result["continuous"]["wall_s"], result["lockstep_vs_serial_x"],
          result["continuous_vs_serial_x"],
          result["labels_identical_to_serial"])],
    )
    print_csv(
        "paced arrivals: offered load vs attained throughput and latency",
        ["offered", "offered_rps", "attained_rps", "queue_p50_s",
         "residence_p50_s", "latency_p95_s", "final_tick"],
        [(name, row["offered_rps"], row["attained_rps"], row["queue_p50_s"],
          row["residence_p50_s"], row["latency_p95_s"],
          row["final_tick_iters"]) for name, row in result["paced"].items()],
    )
    print_csv(
        "SLO curve: attained rps at p95 < X x serial p50",
        list(result["slo_curve"].keys()),
        [tuple(result["slo_curve"].values())],
    )
    assert result["labels_identical_to_serial"], (
        "continuous serving must be bit-identical to serial run_em"
    )
    sweep = result["fault_sweep"]
    print_csv(
        "fault sweep: healthy-lane throughput retention",
        ["rate", "healthy_rps", "retention", "quarantined"],
        [(name, row["healthy_rps"], row["healthy_retention"],
          row.get("quarantined", 0)) for name, row in sweep.items()],
    )
    assert sweep["poison_5pct"]["healthy_retention"] >= 0.9, (
        "healthy-lane throughput must retain >= 90% under 5% poison, got "
        f"{sweep['poison_5pct']['healthy_retention']}"
    )
    assert sweep["poison_5pct"]["healthy_identical_to_serial"], (
        "healthy lanes must stay bit-identical to serial under poison"
    )
    if common.CHECK:
        x = result["continuous_vs_serial_x"]
        assert x >= 1.0, (
            f"continuous serving regressed below serial: {x}x < 1.0x "
            "(the §17 gate; see DESIGN.md §17 for the last post-mortem)"
        )
        light = result["paced"][f"offered_{OFFERED_MULTIPLES[0]}x"]
        p50 = result["serial"]["latency_p50_s"]
        assert light["latency_p50_s"] <= 5.0 * p50, (
            "continuous p50 under light load must stay <= 5x serial p50, "
            f"got {light['latency_p50_s']}s vs serial {p50}s"
        )


if __name__ == "__main__":
    main()
