"""Serving-engine throughput: serial vs lockstep-batched vs continuous.

The workload is the exact case that produced BENCH_api.json's
``batched_speedup_x: 0.45`` inversion: a stream of same-bucket requests
with deliberately mixed convergence iteration counts.  Three ways to serve
it, all through one warm session (compiles excluded from every timing):

* ``serial``       — one warm ``execute()`` per request; each request pays
                     exactly its own iterations, plus per-request dispatch.
* ``lockstep8``    — ``submit()``/``drain()`` micro-batching in groups of
                     8: one vmapped ``run_em_batched`` launch per group, so
                     every lane pays the *slowest* lane's (EM- and
                     MAP-level) iteration count.
* ``continuous8``  — the ticked serving engine (DESIGN.md §12): 8 slots,
                     converged lanes retired and refilled between ticks, so
                     a lane only ever pays its own iterations plus at most
                     one tick of granularity waste.

Emits ``BENCH_serve.json`` with wall/throughput/latency percentiles per
path.  The acceptance target of the serving PR: ``continuous8`` at or
above serial throughput on CPU (lockstep sits well below), with
per-request labels bit-identical to serial ``run_em``.

A fault-rate sweep (0% / 5% / 20% poisoned requests via the chaos
harness's ``bad_init`` class, DESIGN.md §14) measures healthy-lane
throughput retention: poisoned lanes diverge at their first EM boundary
and are quarantined, so the healthy stream's throughput must stay within
10% of the clean run (the fault-tolerance PR's acceptance target at 5%).
"""

from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from benchmarks.common import print_csv
from repro import api
from repro.core import synthetic
from repro.core.pmrf import em as em_mod
from repro.serving import SegmentationEngine
from repro.testing import chaos as chaos_mod

OUT_PATH = pathlib.Path("BENCH_serve.json")
N_REQUESTS = 24
SLOTS = 8
TICK_ITERS = 8
SHAPE = (96, 96)
GRID = (12, 12)
POISON_RATES = (0.05, 0.20)


def _percentiles(lat):
    lat = np.asarray(lat, np.float64)
    return {
        "latency_p50_s": round(float(np.percentile(lat, 50)), 5),
        "latency_p95_s": round(float(np.percentile(lat, 95)), 5),
    }


def run() -> dict:
    jax.clear_caches()
    api.reset_sessions()
    em_mod.reset_trace_counts()

    cfg = api.ExecutionConfig(overseg_grid=GRID, capacity_bucket=4096)
    sess = api.Segmenter(cfg)
    vol = synthetic.make_synthetic_volume(
        seed=0, n_slices=N_REQUESTS, shape=SHAPE
    )
    plans = [sess.plan(np.asarray(im)) for im in vol.images]
    bucket = api.BucketKey(*(max(p.bucket[d] for p in plans) for d in range(3)))

    # Warm every executable + padding memo up front: this bench measures
    # steady-state serving, compiles are BENCH_api.json's subject.
    sess.compile(bucket)
    sess.compile(bucket, batch=SLOTS)
    sess.compile_ticked(bucket, batch=SLOTS, tick_iters=TICK_ITERS)
    serial_results = [
        sess.execute(p, bucket=bucket) for p in plans
    ]  # also warms _pad_plan memos

    # -- serial: per-request latency is each request's own execute. -------
    t0 = time.perf_counter()
    lat_serial = []
    for p in plans:
        t1 = time.perf_counter()
        sess.execute(p, bucket=bucket)
        lat_serial.append(time.perf_counter() - t1)
    serial_wall = time.perf_counter() - t0

    # -- lockstep: groups of 8 through one vmapped launch each. -----------
    t0 = time.perf_counter()
    lat_lockstep = []
    for start in range(0, N_REQUESTS, SLOTS):
        group = plans[start:start + SLOTS]
        t1 = time.perf_counter()
        for p in group:
            sess.submit(p, bucket=bucket)
        sess.drain()
        lat_lockstep.extend([time.perf_counter() - t1] * len(group))
    lockstep_wall = time.perf_counter() - t0

    # -- continuous: the ticked engine over the same stream. ---------------
    engine = SegmentationEngine(
        sess, max_batch=SLOTS, tick_iters=TICK_ITERS, bucket=bucket
    )
    t0 = time.perf_counter()
    for rid, p in enumerate(plans):
        engine.submit(p, rid=rid)
    completions = engine.run()
    continuous_wall = time.perf_counter() - t0
    lat_continuous = [c.latency_s for c in completions]

    # Per-request label bit-identity vs serial run_em (the §12 contract).
    identical = all(
        np.array_equal(c.result.region_labels, serial_results[c.rid].region_labels)
        and np.array_equal(c.result.mu, serial_results[c.rid].mu)
        and c.result.em_iters == serial_results[c.rid].em_iters
        for c in completions
    )

    # -- fault-rate sweep: healthy-lane throughput retention. --------------
    # 0% is the continuous run above; 5% / 20% poison deterministic rids
    # with the bad_init fault (NaN mu0 -> quarantined as `diverged` at the
    # first EM boundary).  Retention compares healthy completions/sec
    # against the clean run's total throughput.
    clean_rps = N_REQUESTS / continuous_wall
    fault_sweep = {
        "poison_0pct": {
            "poisoned_rids": [],
            "wall_s": round(continuous_wall, 4),
            "healthy_rps": round(clean_rps, 3),
            "healthy_retention": 1.0,
        }
    }
    for rate in POISON_RATES:
        k = max(1, round(N_REQUESTS * rate))
        rids = tuple(range(0, N_REQUESTS, max(1, N_REQUESTS // k)))[:k]
        eng = SegmentationEngine(
            sess, max_batch=SLOTS, tick_iters=TICK_ITERS, bucket=bucket
        )
        with chaos_mod.inject(chaos_mod.ChaosConfig(seed=1, bad_init_rids=rids)):
            t0 = time.perf_counter()
            for rid, p in enumerate(plans):
                eng.submit(p, rid=rid)
            comps = eng.run()
            wall = time.perf_counter() - t0
        healthy = [c for c in comps if c.rid not in rids]
        quarantined = [c for c in comps if c.rid in rids]
        healthy_rps = len(healthy) / wall
        fault_sweep[f"poison_{round(rate * 100)}pct"] = {
            "poisoned_rids": list(rids),
            "wall_s": round(wall, 4),
            "healthy_rps": round(healthy_rps, 3),
            "healthy_retention": round(healthy_rps / clean_rps, 3),
            "quarantined": sum(1 for c in quarantined if c.status == "diverged"),
            "healthy_identical_to_serial": all(
                np.array_equal(
                    c.result.region_labels, serial_results[c.rid].region_labels
                )
                for c in healthy
            ),
        }

    em_iters = [r.em_iters for r in serial_results]
    return {
        "n_requests": N_REQUESTS,
        "slots": SLOTS,
        "tick_iters": TICK_ITERS,
        "bucket": list(bucket),
        "backend": cfg.resolved_backend(),
        "jax_backend": jax.default_backend(),
        "em_iters_min_mean_max": [
            int(min(em_iters)),
            round(float(np.mean(em_iters)), 2),
            int(max(em_iters)),
        ],
        "serial": {
            "wall_s": round(serial_wall, 4),
            "throughput_rps": round(N_REQUESTS / serial_wall, 3),
            **_percentiles(lat_serial),
        },
        "lockstep8": {
            "wall_s": round(lockstep_wall, 4),
            "throughput_rps": round(N_REQUESTS / lockstep_wall, 3),
            **_percentiles(lat_lockstep),
        },
        "continuous8": {
            "wall_s": round(continuous_wall, 4),
            "throughput_rps": round(N_REQUESTS / continuous_wall, 3),
            **_percentiles(lat_continuous),
            "engine": engine.stats(),
        },
        "lockstep_vs_serial_x": round(serial_wall / lockstep_wall, 2),
        "continuous_vs_serial_x": round(serial_wall / continuous_wall, 2),
        "labels_identical_to_serial": bool(identical),
        "fault_sweep": fault_sweep,
        "trace_counts": dict(em_mod.TRACE_COUNTS),
    }


def main() -> None:
    result = run()
    OUT_PATH.write_text(json.dumps(result, indent=2) + "\n")
    print_csv(
        f"serving: serial vs lockstep vs continuous -> {OUT_PATH}",
        ["serial_s", "lockstep8_s", "continuous8_s", "lockstep_x",
         "continuous_x", "identical"],
        [(result["serial"]["wall_s"], result["lockstep8"]["wall_s"],
          result["continuous8"]["wall_s"], result["lockstep_vs_serial_x"],
          result["continuous_vs_serial_x"],
          result["labels_identical_to_serial"])],
    )
    assert result["labels_identical_to_serial"], (
        "continuous serving must be bit-identical to serial run_em"
    )
    sweep = result["fault_sweep"]
    print_csv(
        "fault sweep: healthy-lane throughput retention",
        ["rate", "healthy_rps", "retention", "quarantined"],
        [(name, row["healthy_rps"], row["healthy_retention"],
          row.get("quarantined", 0)) for name, row in sweep.items()],
    )
    assert sweep["poison_5pct"]["healthy_retention"] >= 0.9, (
        "healthy-lane throughput must retain >= 90% under 5% poison, got "
        f"{sweep['poison_5pct']['healthy_retention']}"
    )
    assert sweep["poison_5pct"]["healthy_identical_to_serial"], (
        "healthy lanes must stay bit-identical to serial under poison"
    )


if __name__ == "__main__":
    main()
